"""Engine fault-tolerance/speculation on the ARRAY backend, and
cross-backend report agreement.

The planner only sees task sizes, never record data — so for the same
job every scheduling counter and simulated second must agree between the
bytes reference and the device-resident array executor.  These tests
exercise the paths PR 1 only covered via bytes (stragglers, dead-worker
retries) on the array backend, and pin the planner-purity guarantee by
diffing SphereReports across backends."""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import make_cloud
from repro.core import SphereEngine, SphereJob, SphereStage
from repro.core.records import RecordBatch
from repro.core.shuffle import (reduce_partitioner, sample_boundaries,
                                terasort_stages)

REC = 100


def _upload(client, name, n, seed=0, replication=2):
    rng = np.random.default_rng(seed)
    data = rng.bytes(n * REC)
    client.upload(name, data, replication=replication)
    return data


def _identity_job(backend):
    return SphereJob("id", "f",
                     [SphereStage("id", lambda rs: list(rs),
                                  batch_udf=lambda b: b, pad_value=0xFF)],
                     record_size=REC, backend=backend)


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_straggler_speculation(tmp_path, backend):
    """One 50x-slow worker, full replication: speculation must win tasks
    back onto the fast replica — on both record backends."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000,
                                         n_servers=2)
    _upload(client, "f", n=400, replication=2)
    slow = {servers[0].server_id: 0.02, servers[1].server_id: 1.0}
    eng = SphereEngine(master, client, speeds=slow, speculate_factor=1.5)
    outs, rep = eng.run(_identity_job(backend))
    assert rep.speculated > 0
    assert rep.speculation_wins > 0
    assert sum(len(o) for o in outs) == 400 * REC  # nothing lost


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_worker_failure_retry(tmp_path, backend):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=50, replication=3)
    servers[1].kill()
    master.deregister("s1")
    outs, rep = SphereEngine(master, client).run(_identity_job(backend))
    assert len(b"".join(outs)) == len(data)


def _report_key(rep):
    """The backend-independent slice of a SphereReport (partition_seconds
    and udf_traces are real wall-clock / array-only, so excluded)."""
    return (rep.tasks, rep.retried, rep.speculated, rep.speculation_wins,
            rep.bytes_local, rep.bytes_moved, rep.partitioned_records,
            pytest.approx(rep.sim_seconds),
            [pytest.approx(s) for s in rep.stage_seconds])


def _run_both_backends(tmp_path, n, make_job, *, speeds=None, kill=None):
    reports, outputs = {}, {}
    for backend in ("bytes", "array"):
        sub = tmp_path / backend
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        data = _upload(client, "f", n=n, replication=3)
        if kill is not None:
            servers[kill].kill()
            master.deregister(servers[kill].server_id)
        eng = SphereEngine(master, client, speeds=speeds)
        outs, rep = eng.run(make_job(backend, data))
        reports[backend] = rep
        outputs[backend] = outs
    return reports, outputs


def test_report_counters_agree_across_backends(tmp_path):
    """Same TeraSort job on both backends: byte-identical outputs AND an
    identical scheduling report — locality, movement (charged from real
    shuffle origins), speculation and simulated time all match because
    the planner is pure over task sizes."""
    def make_job(backend, data):
        sample = [data[i:i + REC] for i in range(0, 100 * REC, REC)]
        bounds = sample_boundaries(sample, 4, key_bytes=10)
        return SphereJob("sort", "f", terasort_stages(bounds, backend, 4),
                         record_size=REC, backend=backend)

    reports, outputs = _run_both_backends(tmp_path, 100, make_job)
    assert outputs["bytes"] == outputs["array"]
    assert _report_key(reports["array"]) == _report_key(reports["bytes"])
    assert reports["bytes"].sim_seconds > 0
    assert reports["bytes"].bytes_moved > 0  # the shuffle moved something


def test_report_counters_agree_with_failure(tmp_path):
    """Retry counters agree too: chunk reads hit the same dead replicas
    on both backends."""
    reports, outputs = _run_both_backends(
        tmp_path, 60, lambda backend, data: _identity_job(backend), kill=1)
    assert outputs["bytes"] == outputs["array"]
    assert _report_key(reports["array"]) == _report_key(reports["bytes"])


def test_array_udf_traced_once_per_stage(tmp_path):
    """Pad-stable stage UDFs compile once: every task is padded to the
    same block multiple, so rep.udf_traces reports 1 per stage."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=120, replication=2)
    sample = [data[i:i + REC] for i in range(0, 120 * REC, REC)]
    bounds = sample_boundaries(sample, 4, key_bytes=10)
    job = SphereJob("sort", "f", terasort_stages(bounds, "array", 4),
                    record_size=REC, backend="array")
    _, rep = SphereEngine(master, client).run(job)
    assert rep.udf_traces == {"partition": 1, "sort": 1}


def test_array_terasort_stays_on_kernel_path(tmp_path, monkeypatch):
    """10-byte range splitters must take the multi-word kernel — the
    per-record host fallback would be a silent perf regression, so make
    it an error for the whole job."""
    import repro.core.shuffle as shuffle_mod

    def boom(*a, **k):
        raise AssertionError("RangePartitioner fell back to _host_partition")

    monkeypatch.setattr(shuffle_mod, "_host_partition", boom)
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=100, replication=2)
    sample = [data[i:i + REC] for i in range(0, 100 * REC, REC)]
    bounds = sample_boundaries(sample, 4, key_bytes=10)
    assert len(bounds[0]) == 10
    job = SphereJob("sort", "f", terasort_stages(bounds, "array", 4),
                    record_size=REC, backend="array")
    outs, rep = SphereEngine(master, client).run(job)
    allrec = [r for blob in outs
              for r in (blob[i:i + REC] for i in range(0, len(blob), REC))]
    keys = [r[:10] for r in allrec]
    assert keys == sorted(keys) and len(allrec) == 100


def test_same_named_stages_keep_their_own_udfs(tmp_path):
    """The traced-UDF cache is keyed by stage identity, not name — two
    pad-stable stages sharing a name must each run their own batch_udf."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 100, size=50).astype("<u4")
    client.upload("nums", vals.tobytes(), replication=2)

    def add(k):
        return lambda b: type(b)(b.data + np.uint8(k))

    job = SphereJob("dup", "nums", [
        SphereStage("x", batch_udf=add(1), pad_value=0),
        SphereStage("x", batch_udf=add(2), pad_value=0),
    ], record_size=4, backend="array")
    outs, _ = SphereEngine(master, client).run(job)
    got = np.sort(np.frombuffer(b"".join(outs), np.uint8))
    want = np.sort((np.frombuffer(vals.tobytes(), np.uint8) + 3)
                   .astype(np.uint8))
    np.testing.assert_array_equal(got, want)


def _reduce_jobs(backend):
    """An emit job (identity + reduce shuffle to bucket 0) and a chained
    fold job (sum the float32 columns of all records into one record) —
    the k-means-shaped reduce pipeline on tiny inputs."""
    emit = SphereJob(
        "emit", "f",
        [SphereStage("emit", lambda rs: list(rs), batch_udf=lambda b: b,
                     pad_value=0, partitioner=reduce_partitioner())],
        record_size=8, backend=backend)

    def fold_bytes(records):
        tot = np.sum([np.frombuffer(r, "<f4") for r in records], axis=0,
                     dtype=np.float32)
        return [tot.astype("<f4").tobytes()]

    # array fold: bitcast rows to f32, zero out padding via mask, sum
    import jax

    def fold_masked(batch, mask, _params):
        arr = jax.lax.bitcast_convert_type(
            batch.data.reshape(batch.num_records, -1, 4), jnp.float32)
        arr = arr * mask.astype(jnp.float32)[:, None]
        raw = jax.lax.bitcast_convert_type(arr.sum(0, keepdims=True),
                                           jnp.uint8)
        return RecordBatch(raw.reshape(1, -1))

    fold = SphereJob(
        "fold", "f",
        [SphereStage("fold", fold_bytes, masked_udf=fold_masked)],
        record_size=8, backend=backend)
    return emit, fold


def test_chained_reduce_tiny_batch_backend_parity(tmp_path, monkeypatch):
    """The reduce path must not silently drop to the per-record host loop
    (the bytes-path fallback) — even when a chained job's whole input is
    a single tiny batch of partials.  reduce_partitioner stays on the
    array path, the mask-aware fold stays at its fixed block shape, and
    both backends agree on outputs AND scheduling reports."""
    import repro.core.shuffle as shuffle_mod

    def boom(*a, **k):
        raise AssertionError("reduce path fell back to _host_partition")

    monkeypatch.setattr(shuffle_mod, "_host_partition", boom)
    # integer-valued floats: sums are exact in f4 and f8 alike, so the
    # two backends' outputs are byte-identical
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 1000, size=(40, 2)).astype("<f4")

    results = {}
    for backend in ("bytes", "array"):
        sub = tmp_path / backend
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        client.upload("f", vals.tobytes(), replication=2)
        emit, fold = _reduce_jobs(backend)
        sess = SphereEngine(master, client).session("f", record_size=8,
                                                    backend=backend)
        sess.run(emit)
        outs, rep = sess.run(fold, input="chained")
        results[backend] = (outs, rep)
        assert len(outs) == 1  # one folded record
        np.testing.assert_allclose(np.frombuffer(outs[0], "<f4"),
                                   vals.sum(0))
    assert results["bytes"][0] == results["array"][0]
    assert _report_key(results["array"][1]) == _report_key(results["bytes"][1])
    assert results["array"][1].udf_traces["fold"] == 1


def _terasort_job(backend, data, n_buckets=4):
    sample = [data[i:i + REC] for i in range(0, min(len(data), 100 * REC),
                                             REC)]
    bounds = sample_boundaries(sample, n_buckets, key_bytes=10)
    return SphereJob("sort", "f", terasort_stages(bounds, backend,
                                                  n_buckets),
                     record_size=REC, backend=backend)


def test_host_syncs_one_per_shuffle_round(tmp_path):
    """The dispatch-then-sync invariant: an array kernel-path shuffle
    round costs exactly ONE host sync (the batched histogram barrier),
    never one per worker batch — and the bytes backend, which never puts
    data on device, reports zero while agreeing on the round count."""
    for backend, sub in (("bytes", "b"), ("array", "a")):
        d = tmp_path / sub
        d.mkdir()
        master, servers, client = make_cloud(d, chunk_size=1000)
        data = _upload(client, "f", n=200, replication=2)
        _, rep = SphereEngine(master, client).run(
            _terasort_job(backend, data))
        assert rep.shuffle_rounds == 1       # one non-final stage
        if backend == "array":
            assert rep.host_syncs == rep.shuffle_rounds
        else:
            assert rep.host_syncs == 0


def test_host_syncs_reduce_round_is_free(tmp_path):
    """Reduce rounds resolve at dispatch (single-bucket short circuit):
    the round counts in shuffle_rounds but syncs nothing — host_syncs
    stays <= shuffle_rounds in general, equal only on kernel rounds."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    rng = np.random.default_rng(11)
    client.upload("f", rng.integers(0, 1000, size=(40, 2)).astype("<f4")
                  .tobytes(), replication=2)
    emit, fold = _reduce_jobs("array")
    sess = SphereEngine(master, client).session("f", record_size=8,
                                                backend="array")
    _, rep = sess.run(emit)
    assert rep.shuffle_rounds == 1 and rep.host_syncs == 0
    _, rep2 = sess.run(fold, input="chained")
    assert rep2.host_syncs == 0


def test_host_syncs_chained_terasort_rounds(tmp_path):
    """A chained session re-running the sort keeps the one-sync-per-round
    invariant on every job in the chain."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=150, replication=2)
    sess = SphereEngine(master, client).session("f", record_size=REC,
                                                backend="array")
    job = _terasort_job("array", data)
    _, rep1 = sess.run(job)
    _, rep2 = sess.run(job, input="chained")
    for rep in (rep1, rep2):
        assert rep.shuffle_rounds == 1
        assert rep.host_syncs == rep.shuffle_rounds


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_prefetch_matches_synchronous_path(tmp_path, backend):
    """Stage-0 decode prefetch is result-identical: same outputs, same
    report (including retry counters) as prefetch=False — with a dead
    server in the mix so the failure-replay path is exercised."""
    results = {}
    for prefetch in (True, False):
        sub = tmp_path / f"{backend}-{prefetch}"
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        data = _upload(client, "f", n=120, replication=3)
        servers[2].kill()
        master.deregister(servers[2].server_id)
        eng = SphereEngine(master, client, prefetch=prefetch)
        outs, rep = eng.run(_terasort_job(backend, data))
        results[prefetch] = (outs, rep)
    assert results[True][0] == results[False][0]
    assert _report_key(results[True][1]) == _report_key(results[False][1])
    assert results[True][1].retried == results[False][1].retried


def test_stream_windows_backend_parity_with_overlap(tmp_path):
    """Two sliding windows of a TeraSort stream: byte-identical window
    outputs across backends under the dispatch-then-sync shuffle and
    prefetch, with the one-sync-per-round invariant holding per window
    on the array side."""
    from repro.core import WindowPolicy

    outs = {}
    for backend in ("bytes", "array"):
        sub = tmp_path / backend
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        eng = SphereEngine(master, client)
        stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                            record_size=REC, backend=backend)
        datas = [_upload(client, f"s/{i}", n=60, seed=i, replication=2)
                 for i in range(3)]
        sample = [datas[0][i:i + REC] for i in range(0, 60 * REC, REC)]
        bounds = sample_boundaries(sample, 4, key_bytes=10)
        job = SphereJob("sort", "s/", terasort_stages(bounds, backend, 4),
                        record_size=REC, backend=backend)
        # 3 arrivals under sliding(2): the trailing window (s/1, s/2) is
        # current — run the job against it
        assert stream.windows_formed == 2
        o, rep = stream.run(job)
        outs[backend] = [(o, rep)]
        if backend == "array":
            assert rep.shuffle_rounds == 1
            assert rep.host_syncs == rep.shuffle_rounds
    assert outs["bytes"][0][0] == outs["array"][0][0]
    assert (_report_key(outs["bytes"][0][1])
            == _report_key(outs["array"][0][1]))


def test_fused_rounds_match_unfused_and_bytes(tmp_path):
    """The fused worker-axis round (stacked UDF apply + one-round scatter
    + device regrouping) is only allowed to exist because it agrees with
    both the per-worker array loop and the bytes reference —
    byte-identical outputs AND identical scheduling reports."""
    results = {}
    for label, backend, fused in (("bytes", "bytes", False),
                                  ("array", "array", False),
                                  ("fused", "array", True)):
        sub = tmp_path / label
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        data = _upload(client, "f", n=200, replication=3)
        eng = SphereEngine(master, client, fused_rounds=fused)
        outs, rep = eng.run(_terasort_job(backend, data, n_buckets=6))
        results[label] = (outs, rep)
    assert results["fused"][0] == results["array"][0]
    assert results["fused"][0] == results["bytes"][0]
    assert _report_key(results["fused"][1]) == _report_key(results["bytes"][1])
    assert _report_key(results["fused"][1]) == _report_key(results["array"][1])
    # and the fused round kept the one-sync-per-round invariant
    assert results["fused"][1].host_syncs == results["fused"][1].shuffle_rounds


def test_fused_dispatches_constant_in_workers_and_tasks(tmp_path):
    """The tentpole invariant: a fused round costs O(1) compiled
    dispatches — one stacked UDF call, a bounded shard fan of scatter
    calls, one regrouping gather — regardless of worker count or task
    count, where the per-task/per-worker loop grows linearly."""
    from repro.core.shuffle import _ROUND_MAX_SHARDS

    def run(n_servers, n_records, fused):
        sub = tmp_path / f"{n_servers}-{n_records}-{fused}"
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000,
                                             n_servers=n_servers)
        data = _upload(client, "f", n=n_records, replication=2)
        eng = SphereEngine(master, client, fused_rounds=fused)
        _, rep = eng.run(_terasort_job("array", data))
        return rep

    # ceiling: stacked apply + shard fan + harvest gather + next stage
    cap = _ROUND_MAX_SHARDS + 4
    small = run(2, 100, True)
    wide = run(6, 100, True)
    many = run(6, 400, True)     # 4x the tasks
    for rep in (small, wide, many):
        assert 0 < rep.device_dispatches <= cap
        assert rep.shuffle_rounds == 1
    assert wide.device_dispatches == small.device_dispatches
    assert many.device_dispatches <= small.device_dispatches + \
        _ROUND_MAX_SHARDS - 1    # shard fan may widen, nothing else may
    # the per-task loop's count grows with tasks (the contrast the
    # fused invariant is measured against)
    loopy = run(6, 400, False)
    assert loopy.device_dispatches > cap


def test_prefetch_depth_reports_bit_identical(tmp_path):
    """Deeper stage-0 prefetch pipelines are a pure latency knob: every
    depth (and prefetch off) yields byte-identical outputs and identical
    reports, including retry counters under a dead server."""
    results = {}
    for depth in (0, 1, 3, 8):
        sub = tmp_path / f"d{depth}"
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        data = _upload(client, "f", n=120, replication=3)
        servers[2].kill()
        master.deregister(servers[2].server_id)
        eng = SphereEngine(master, client, prefetch=depth > 0,
                           prefetch_depth=max(depth, 1))
        outs, rep = eng.run(_terasort_job("array", data))
        results[depth] = (outs, rep)
    base = results[0]
    for depth in (1, 3, 8):
        assert results[depth][0] == base[0]
        assert _report_key(results[depth][1]) == _report_key(base[1])
        assert results[depth][1].retried == base[1].retried


def test_pad_unstable_udf_is_rejected(tmp_path):
    """A batch_udf that changes the row count while declaring pad_value
    violates the pad-stability contract and must fail loudly."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=20, replication=2)
    job = SphereJob("bad", "f",
                    [SphereStage("halve",
                                 batch_udf=lambda b: b.take(
                                     np.arange(b.num_records // 2)),
                                 pad_value=0xFF)],
                    record_size=REC, backend="array")
    with pytest.raises(ValueError, match="pad-stable"):
        SphereEngine(master, client).run(job)
