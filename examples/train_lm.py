"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

The full production path at laptop scale: synthetic corpus -> Sector
(replicated chunks) -> locality-aware pipeline -> train step (fwd/bwd UDF +
gradient shuffle + optimizer UDF) -> Sector-replicated checkpoints, with a
mid-run chunk-server failure, repair, and checkpoint-resume demonstration.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.data import DataPipeline, SectorTokenDataset, write_synthetic_corpus
from repro.parallel.sharding import ParallelConfig
from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.sector.replication import ReplicationDaemon
from repro.train import SectorCheckpointer, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M params: 8 layers, d=512, vocab 50k
cfg = get_config("qwen2.5-3b").replace(
    name="qwen2.5-100m", n_layers=args.layers, d_model=args.d_model,
    n_heads=8, n_kv_heads=2, d_head=64, d_ff=2048, vocab_size=50304,
    tie_embeddings=True)
print(f"model: {cfg.param_count()/1e6:.1f}M params")

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=512 * 1024)
servers = [ChunkServer(f"s{i}", master.topology.sites[i % 6], tmp)
           for i in range(6)]
for s in servers:
    master.register(s)
master.acl.add_member("trainer")
master.acl.grant_write("trainer")
client = SectorClient(master, "trainer", "chicago")

write_synthetic_corpus(client, "corpus", 4_000_000, cfg.vocab_size)
ds = SectorTokenDataset(master, client, "corpus", seq_len=args.seq)
pcfg = ParallelConfig(mesh=None, remat="none")
pipe = DataPipeline(ds, batch=args.batch, pcfg=pcfg)
ckpt = SectorCheckpointer(client, "train-lm")
trainer = Trainer(cfg, pcfg,
                  TrainerConfig(steps=args.steps, ckpt_every=100,
                                log_every=20, lr=6e-4, warmup=40),
                  pipe, ckpt)

half = args.steps // 2
trainer.run(half)

# --- mid-run failure: kill a chunk server, detect, repair, keep training ---
print("\n!! killing chunk server s1 mid-run")
daemon = ReplicationDaemon(master, client)
servers[1].kill()
for t in (0.0, 35.0):
    for s in servers:
        if s.alive:
            master.heartbeat(s.server_id, t)
rep = daemon.tick(35.0)
print(f"detected failed={rep['failed']}, re-replicated "
      f"{rep['repaired']} chunks; under-replicated now: "
      f"{master.stats()['under_replicated']}\n")

trainer.run(args.steps - half)
for h in trainer.history:
    print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
          f"grad_norm {h['grad_norm']:.2f}")
first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
print(f"\nloss {first:.3f} -> {last:.3f} "
      f"({'OK: learning' if last < first - 0.5 else 'WARN: check lr'}); "
      f"data locality {ds.locality_fraction:.0%}; "
      f"checkpoints at steps {ckpt.steps()}")
