"""Wide-area TeraSort on the Open Cloud Testbed (arXiv:0907.4810).

    PYTHONPATH=src python examples/wan_terasort.py

Four sites — Baltimore, StarLight, UIC, Calit2 — joined by shared
10 Gbps waves.  Sort files land at each site as they are generated
(timed stream windows bucket them by landing time, with a grace period
for the slow transcontinental site), and each window's TeraSort chases
the data: the contention-aware planner keeps chunks on their landing
site's workers, prices the cross-site shuffle with per-link queueing,
and reports how long transfers sat behind each other on the shared
waves (``link_wait_seconds``).

The same window set is then re-run on a contention-BLIND engine: its
plans look faster on paper precisely because they price every flow on a
private link — the gap is the over-commit the aware planner refuses to
believe in.
"""
import tempfile

import numpy as np

from repro.core import SphereEngine, SphereJob, WindowPolicy
from repro.core.shuffle import sample_boundaries, terasort_stages
from repro.core.stream import SphereStream
from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.sector.topology import OPEN_CLOUD_TESTBED

RECORD, KEY = 100, 10
RECS_PER_FILE = 4_000
SPAN, GRACE = 60.0, 15.0      # window: 1 simulated minute, 15 s grace

rng = np.random.default_rng(0)
tmp = tempfile.mkdtemp()
master = SectorMaster(topology=OPEN_CLOUD_TESTBED,
                      chunk_size=1000 * RECORD, llpr_placement=True)
for site in OPEN_CLOUD_TESTBED.sites:
    for k in range(2):
        master.register(ChunkServer(f"{site}{k}", site, tmp))
master.acl.add_member("u")
master.acl.grant_write("u")

# one uploading client per site: files land where they were generated,
# and LLPR-weighted placement anchors replicas near the writer
clients = {site: SectorClient(master, "u", site)
           for site in OPEN_CLOUD_TESTBED.sites}
engine = SphereEngine(master, clients["baltimore"])

# ---- stream: timed windows over files landing at all four sites -------
stream = engine.stream("wan/", window=WindowPolicy.timed(SPAN, GRACE),
                       record_size=RECORD)
windows = []
stream.on_window(lambda s, idx, files: windows.append((idx, files)))


def make_file(n: int) -> bytes:
    return b"".join(rng.bytes(KEY) + b"v" * (RECORD - KEY)
                    for _ in range(n))


# landing schedule: (simulated landing time, site).  Calit2's second
# file is LATE — it lands after its window's watermark already passed
# (the grace period saves the first straggler, not this one).
landings = [
    (5.0, "baltimore"), (12.0, "starlight"), (20.0, "uic"),
    (48.0, "calit2"),                         # slow site, inside grace
    (65.0, "baltimore"), (70.0, "uic"), (90.0, "starlight"),
    (130.0, "starlight"), (140.0, "uic"),     # third window opens
    (41.0, "calit2"),                         # LATE: window 0 already fired
]
payloads = {}
for i, (at, site) in enumerate(landings):
    name = f"wan/{i:03d}_{site}"
    payloads[name] = make_file(RECS_PER_FILE)
    clients[site].upload(name, payloads[name], replication=2, at=at)
stream.advance_watermark(200.0)               # flush the final window

print(f"windows formed: {stream.windows_formed}, "
      f"late files dropped: {stream.late_dropped}")
assert stream.late_dropped == 1               # the 41.0 s calit2 file

# ---- per-window TeraSort, compute chasing the data's landing sites ----
reports = []
for idx, files in windows:
    sample = [payloads[files[0]][i:i + RECORD]
              for i in range(0, 500 * RECORD, RECORD)]
    bounds = sample_boundaries(sample, 8, key_bytes=KEY)
    job = SphereJob("wan_terasort", stream.job_input_name,
                    terasort_stages(bounds, "bytes", 8, key_bytes=KEY),
                    record_size=RECORD, backend="bytes")
    # rebuild a pinned stream per window (the demo keeps every window's
    # file set around so the blind re-run below sees identical input)
    win = SphereStream(engine, files=files, record_size=RECORD)
    outputs, rep = win.run(job)
    win.close()
    reports.append(rep)
    total = sum(len(b) // RECORD for b in outputs)
    print(f"window {idx}: files={len(files)} sorted={total} "
          f"sim={rep.sim_seconds:.3f}s locality={rep.locality_fraction:.0%} "
          f"link_wait={rep.link_wait_seconds:.3f}s")
    prev_last = b""
    for blob in outputs:
        recs = [blob[i:i + RECORD] for i in range(0, len(blob), RECORD)]
        assert recs == sorted(recs, key=lambda r: r[:KEY])
        if recs:
            assert recs[0][:KEY] >= prev_last
            prev_last = recs[-1][:KEY]

# ---- the same windows, priced contention-blind ------------------------
blind_engine = SphereEngine(master, clients["baltimore"],
                            contention_aware=False)
blind_total = 0.0
for idx, files in windows:
    sample = [payloads[files[0]][i:i + RECORD]
              for i in range(0, 500 * RECORD, RECORD)]
    bounds = sample_boundaries(sample, 8, key_bytes=KEY)
    job = SphereJob("wan_terasort", "ignored",
                    terasort_stages(bounds, "bytes", 8, key_bytes=KEY),
                    record_size=RECORD, backend="bytes")
    win = SphereStream(blind_engine, files=files, record_size=RECORD)
    _, rep = win.run(job)
    win.close()
    blind_total += rep.sim_seconds

aware_total = sum(r.sim_seconds for r in reports)
print(f"aware total sim: {aware_total:.3f}s   "
      f"blind (private-link) estimate: {blind_total:.3f}s   "
      f"over-commit hidden by blind pricing: "
      f"{aware_total / max(blind_total, 1e-9):.2f}x")
assert aware_total >= blind_total  # queued waves can only add time
