"""Angle (paper §5.3): anomaly detection over distributed TCP-flow features.

Sensor nodes at four sites package anonymised packet windows into feature
files stored in Sector; Sphere clusters each window with k-means; a temporal
analysis of the per-window cluster models flags anomalous behaviour.

    PYTHONPATH=src python examples/angle_kmeans.py [--backend {array,bytes}]

``--backend array`` (default) clusters each window with the mask-aware
RecordBatch UDFs; ``--backend bytes`` is the per-chunk numpy reference.
Each window's iterations chain through one :class:`SphereSession` — one
Sector lookup and one traced UDF pair per window, however many k-means
iterations run over it.
"""
import argparse
import tempfile

import numpy as np

from repro.core import SphereEngine
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.sector import ChunkServer, SectorClient, SectorMaster

SITES = ["chicago", "greenbelt", "pasadena", "tokyo"]  # sensor sites
DIM, K, WINDOWS = 6, 4, 8

ap = argparse.ArgumentParser()
ap.add_argument("--backend", choices=("array", "bytes"), default="array")
backend = ap.parse_args().backend

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=96 * 1024)
for i, site in enumerate(SITES * 2):
    master.register(ChunkServer(f"s{i}", site, tmp))
master.acl.add_member("angle")
master.acl.grant_write("angle")
client = SectorClient(master, "angle", "chicago")

rng = np.random.default_rng(0)
normal_centers = rng.normal(size=(K, DIM)) * 3

engine = SphereEngine(master, client)
record_size = 4 * DIM if backend == "array" else 0

# windows 0..5 are normal traffic; 6-7 contain an injected anomaly cluster
models = []
for w in range(WINDOWS):
    pts = np.concatenate([
        rng.normal(c, 0.4, size=(400, DIM)) for c in normal_centers])
    if w >= 6:  # suspicious behaviour: a new tight cluster far away
        pts = np.concatenate([pts, rng.normal(12.0, 0.2, size=(150, DIM))])
    file = f"angle/window_{w:03d}.f32"
    client.upload(file, encode_points(pts.astype(np.float32)), replication=2)
    session = engine.session(file, record_size=record_size, backend=backend)
    cents, rep = kmeans_sphere(engine, file,
                               dim=DIM, k=K + 1, iters=6, seed=1,
                               backend=backend, session=session)
    models.append(cents)
    print(f"window {w}: clustered in {session.jobs_run} chained jobs "
          f"(locality {rep.locality_fraction:.0%}, "
          f"sim {rep.sim_seconds:.2f}s, traces {dict(rep.udf_traces)})")

# temporal analysis: alert when a window's cluster model drifts
baseline = np.stack(models[:4]).mean(0)


def drift(m):
    # symmetric chamfer distance between centroid sets
    d = np.linalg.norm(m[:, None] - baseline[None], axis=-1)
    return 0.5 * (d.min(0).mean() + d.min(1).mean())

scores = [drift(m) for m in models]
thresh = np.mean(scores[:6]) + 4 * np.std(scores[:6])
print("\nwindow drift scores:",
      " ".join(f"{s:.2f}" for s in scores))
alerts = [w for w, s in enumerate(scores) if s > thresh]
print(f"ALERTS at windows {alerts} (expected [6, 7])")
assert alerts == [6, 7]
