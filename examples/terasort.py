"""TeraSort on Sphere (paper §5.4): distributed sort of 100-byte records.

    PYTHONPATH=src python examples/terasort.py
"""
import tempfile

import numpy as np

from repro.core import SphereEngine, SphereJob, SphereStage
from repro.core.shuffle import range_partitioner, sample_boundaries
from repro.sector import ChunkServer, SectorClient, SectorMaster

RECORD, KEY, N = 100, 10, 20_000

rng = np.random.default_rng(0)
payload = b"".join(rng.bytes(KEY) + b"v" * (RECORD - KEY) for _ in range(N))

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=2000 * RECORD)
for i, site in enumerate(master.topology.sites):
    master.register(ChunkServer(f"s{i}", site, tmp))
master.acl.add_member("u")
master.acl.grant_write("u")
client = SectorClient(master, "u", "chicago")
client.upload("tera", payload, replication=3)

# sample splitters, then: partition stage (shuffle) -> sort stage
sample = [payload[i:i + RECORD] for i in range(0, 500 * RECORD, RECORD)]
bounds = sample_boundaries(sample, 6, key_bytes=KEY)
job = SphereJob("terasort", "tera", [
    SphereStage("partition", lambda rs: list(rs),
                partitioner=range_partitioner(bounds), n_buckets=6),
    SphereStage("sort", lambda rs: sorted(rs, key=lambda r: r[:KEY])),
], record_size=RECORD)

outputs, rep = SphereEngine(master, client).run(job)

# verify: each bucket sorted, buckets ordered, nothing lost
prev_last = b""
total = 0
for blob in outputs:
    recs = [blob[i:i + RECORD] for i in range(0, len(blob), RECORD)]
    assert recs == sorted(recs, key=lambda r: r[:KEY])
    if recs:
        assert recs[0][:KEY] >= prev_last
        prev_last = recs[-1][:KEY]
    total += len(recs)
assert total == N
print(f"sorted {N} records across {len(outputs)} buckets: OK")
print(f"tasks={rep.tasks} locality={rep.locality_fraction:.0%} "
      f"bytes_moved={rep.bytes_moved} sim_time={rep.sim_seconds:.2f}s")
