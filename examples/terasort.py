"""TeraSort on Sphere (paper §5.4): distributed sort of 100-byte records.

    PYTHONPATH=src python examples/terasort.py [--backend {array,bytes}]

``--backend array`` (default) packs records into RecordBatches and
partitions with the Pallas bucket-partition kernel; ``--backend bytes``
is the per-record Python reference path. Both produce the same output.
"""
import argparse
import tempfile

import numpy as np

from repro.core import SphereEngine, SphereJob
from repro.core.shuffle import sample_boundaries, terasort_stages
from repro.sector import ChunkServer, SectorClient, SectorMaster

RECORD, KEY, N = 100, 10, 20_000

ap = argparse.ArgumentParser()
ap.add_argument("--backend", choices=("array", "bytes"), default="array")
backend = ap.parse_args().backend

rng = np.random.default_rng(0)
payload = b"".join(rng.bytes(KEY) + b"v" * (RECORD - KEY) for _ in range(N))

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=2000 * RECORD)
for i, site in enumerate(master.topology.sites):
    master.register(ChunkServer(f"s{i}", site, tmp))
master.acl.add_member("u")
master.acl.grant_write("u")
client = SectorClient(master, "u", "chicago")
client.upload("tera", payload, replication=3)

# sample splitters, then: partition stage (shuffle) -> sort stage.
# full 10-byte splitters: the kernel's multi-word lexicographic compare
# matches the bytes comparison for any boundary length (core/shuffle.py).
sample = [payload[i:i + RECORD] for i in range(0, 500 * RECORD, RECORD)]
bounds = sample_boundaries(sample, 6, key_bytes=KEY)
job = SphereJob("terasort", "tera",
                terasort_stages(bounds, backend, 6, key_bytes=KEY),
                record_size=RECORD, backend=backend)

outputs, rep = SphereEngine(master, client).run(job)

# verify: each bucket sorted, buckets ordered, nothing lost
prev_last = b""
total = 0
for blob in outputs:
    recs = [blob[i:i + RECORD] for i in range(0, len(blob), RECORD)]
    assert recs == sorted(recs, key=lambda r: r[:KEY])
    if recs:
        assert recs[0][:KEY] >= prev_last
        prev_last = recs[-1][:KEY]
    total += len(recs)
assert total == N
print(f"[{backend} backend] sorted {N} records across "
      f"{len(outputs)} buckets: OK")
print(f"tasks={rep.tasks} locality={rep.locality_fraction:.0%} "
      f"bytes_moved={rep.bytes_moved} sim_time={rep.sim_seconds:.2f}s "
      f"partition={rep.partitioned_records / max(rep.partition_seconds, 1e-9):,.0f} rec/s")
