"""Quickstart: stand up a Sector cloud, store data, run a Sphere job.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.core import SphereEngine, SphereJob, SphereStage
from repro.sector import ChunkServer, SectorClient, SectorMaster

# --- 1. a wide-area storage cloud: 6 servers across the Teraflow sites ----
tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=100 * 1000)
for i, site in enumerate(master.topology.sites):
    master.register(ChunkServer(f"server-{i}", site, tmp))

# community ACL: public reads, member writes (paper §3, Fig. 3)
master.acl.add_member("alice")
master.acl.grant_write("alice")
alice = SectorClient(master, "alice", site="chicago")

# --- 2. store a replicated dataset ------------------------------------------
rng = np.random.default_rng(0)
values = rng.integers(0, 1000, size=100_000).astype("<u4")
alice.upload("demo/values.u32", values.tobytes(), replication=3)
print("stored:", master.stats())

# anyone can read, from the nearest replica over (simulated) UDT
public = SectorClient(master, "public", site="tokyo")
blob = public.download("demo/values.u32")
print("public read ok:", np.frombuffer(blob, '<u4').shape,
      f"sim transfer {public.log.sim_seconds:.2f}s over the WAN")

# --- 3. a Sphere job: the paper's `sphere.run(data, process)` ----------------
#    for each record: process(record)   -- runs where the data lives

def process(records):
    """Square every value (the paper's §4 loop body)."""
    out = []
    for r in records:
        v = np.frombuffer(r, "<u4")
        out.append((v.astype("<u8") ** 2).tobytes())
    return out

job = SphereJob("square", "demo/values.u32",
                [SphereStage("square", process)], record_size=4)
outputs, report = SphereEngine(master, alice).run(job)

got = np.sort(np.concatenate([np.frombuffer(b, "<u8") for b in outputs]))
want = np.sort(values.astype("<u8") ** 2)
assert np.array_equal(got, want)
print(f"sphere.run ok: {report.tasks} tasks, "
      f"locality={report.locality_fraction:.0%}, "
      f"sim time {report.sim_seconds:.2f}s")
