"""Angle continuous mining (paper §5.3 + arXiv:0808.3019): streaming
windowed k-means over TCP-flow feature files AS THEY LAND in Sector.

Sensor nodes at four sites continuously package anonymised packet windows
into feature files.  Unlike ``angle_kmeans.py`` — which opens a fresh
session per window file — this example never polls: a
:class:`SphereStream` subscribes to the ``angle/window_`` path prefix on
the master's event bus, every upload's ``file-created`` event advances a
sliding window over the newest files, and the per-window callback fits a
warm-started k-means **during the upload that completed the window**
(compute follows the data).  Across the whole stream:

* each window plans only the delta — the one new file's chunks; the
  surviving files keep their cached plans and device-resident chunks;
* the k-means stages trace exactly once (``udf_traces == 1``) for every
  window and iteration, because the stage pair persists and centroids
  ride along as a dynamic jit argument;
* each window's model warm-starts from the previous window's, and the
  model sequence feeds the temporal anomaly detector.

    PYTHONPATH=src python examples/angle_stream.py [--backend {array,bytes}]
"""
import argparse
import tempfile

import numpy as np

from repro.core import SphereEngine, WindowPolicy
from repro.core.kmeans import StreamingKMeans, encode_points
from repro.sector import ChunkServer, SectorClient, SectorMaster

SITES = ["chicago", "greenbelt", "pasadena", "tokyo"]  # sensor sites
DIM, K = 6, 4
FILES, WIN = 9, 4          # 9 arriving files -> 6 sliding windows

ap = argparse.ArgumentParser()
ap.add_argument("--backend", choices=("array", "bytes"), default="array")
backend = ap.parse_args().backend

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=96 * 1024)  # 4096 records of 24 B
for i, site in enumerate(SITES * 2):
    master.register(ChunkServer(f"s{i}", site, tmp))
master.acl.add_member("angle")
master.acl.grant_write("angle")
client = SectorClient(master, "angle", "chicago")

engine = SphereEngine(master, client)
record_size = 4 * DIM if backend == "array" else 0
stream = engine.stream("angle/window_", window=WindowPolicy.sliding(WIN),
                       record_size=record_size, backend=backend)
skm = StreamingKMeans(stream, DIM, K + 1, iters=4)  # spare centroid

models = []


def on_window(s, idx, files):
    before = (skm.report.planned_tasks, skm.report.reused_tasks)
    models.append(skm.fit_window())
    planned = skm.report.planned_tasks - before[0]
    reused = skm.report.reused_tasks - before[1]
    print(f"window {idx} [{files[0].split('_')[-1]}..{files[-1].split('_')[-1]}]"
          f": planned {planned} delta chunks, replayed {reused}, "
          f"traces {dict(skm.report.udf_traces)}")


stream.on_window(on_window)

# the sensor feed: files 0..6 are normal traffic, files 7-8 carry an
# injected anomaly cluster (landing in sliding windows 4 and 5).  Each
# upload's file-created event drives the windowing and (synchronously)
# the per-window clustering above.
rng = np.random.default_rng(0)
normal_centers = rng.normal(size=(K, DIM)) * 3
for w in range(FILES):
    pts = np.concatenate([
        rng.normal(c, 0.4, size=(400, DIM)) for c in normal_centers])
    if w >= 7:  # suspicious behaviour: a new tight cluster far away
        pts = np.concatenate([pts, rng.normal(12.0, 0.2, size=(150, DIM))])
    client.upload(f"angle/window_{w:03d}.f32",
                  encode_points(pts.astype(np.float32)), replication=2)

n_windows = FILES - WIN + 1
assert stream.windows_formed == n_windows == len(models)
if backend == "array":
    assert skm.report.udf_traces == {"assign": 1, "fold": 1}, \
        "stage UDFs must trace once across the entire stream"

# temporal analysis: alert when a window's cluster model drifts from the
# all-normal early windows
baseline = np.stack(models[:3]).mean(0)


def drift(m):
    # symmetric chamfer distance between centroid sets
    d = np.linalg.norm(m[:, None] - baseline[None], axis=-1)
    return 0.5 * (d.min(0).mean() + d.min(1).mean())


scores = [drift(m) for m in models]
# normal windows drift ~0.01 (warm starts keep the model pinned); the
# chamfer mean dilutes a single escaping centroid by 1/(K+1), so the
# anomaly windows land around 0.5-1.0 — a 0.1 floor splits them cleanly
thresh = max(np.mean(scores[:3]) + 4 * np.std(scores[:3]), 0.1)
print("\nwindow drift scores:", " ".join(f"{s:.2f}" for s in scores))
alerts = [w for w, s in enumerate(scores) if s > thresh]
# the anomaly files (7, 8) fall inside sliding windows 4 and 5
print(f"ALERTS at windows {alerts} (expected [4, 5])")
assert alerts == [4, 5]
