"""SDSS content distribution (paper §5.2): serve a large e-science dataset
to astronomers worldwide from replicated Sector storage.

Reports per-site download throughput and LLPR, mirroring Table 1.

    PYTHONPATH=src python examples/sdss_distribution.py
"""
import tempfile

import numpy as np

from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.sector.transport import HOST_RATE

tmp = tempfile.mkdtemp()
master = SectorMaster(chunk_size=1 * 1024 * 1024)
for i, site in enumerate(master.topology.sites * 2):
    master.register(ChunkServer(f"s{i}", site, tmp))
master.acl.add_member("ncdm")
master.acl.grant_write("ncdm")
admin = SectorClient(master, "ncdm", "chicago")

# "DR5 catalog files" (scaled down): 8 files x 4 MB, 3 replicas each
rng = np.random.default_rng(0)
files = {}
for i in range(8):
    name = f"sdss/dr5/catalog_{i:02d}.fits"
    data = rng.bytes(4 * 1024 * 1024)
    files[name] = data
    admin.upload(name, data, replication=3)
print(f"published {len(files)} files, {master.stats()['chunks']} chunks, "
      f"3-way replicated across {len(master.topology.sites)} sites\n")

# astronomers at every site download; reads hit the nearest replica
print(f"{'site':12s} {'MB':>6s} {'sim_s':>7s} {'Mb/s':>7s} {'LLPR':>6s}")
local_rate = HOST_RATE / 1e6
for site in master.topology.sites:
    user = SectorClient(master, "astronomer", site)
    nbytes = 0
    for name, want in files.items():
        got = user.download(name)
        assert got == want
        nbytes += len(got)
    mbps = nbytes * 8 / user.log.sim_seconds / 1e6
    print(f"{site:12s} {nbytes/1e6:6.1f} {user.log.sim_seconds:7.2f} "
          f"{mbps:7.0f} {min(mbps/local_rate, 1.0):6.2f}")

print("\n(cf. paper: 5000 accesses, 200TB served since July 2006; "
      "LLPR 0.61-0.98)")
